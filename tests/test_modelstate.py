"""Model-state plane: checkpoint registry, contention-aware load
engine, recovery scheduler, locality planner — and the golden
fingerprint pinning that proves the default config is bit-identical to
the pre-model-state behavior."""

import hashlib

import pytest

from repro.core.cluster import make_cluster
from repro.core.controller import LoadExecutor, RecoveryScheduler
from repro.core.heartbeat import SimClock
from repro.core.modelstate import (CLOUD, LOCAL, PEER, LoadCostModel,
                                   ModelRegistry, StorageConfig,
                                   storage_preset)
from repro.core.scenario import SCENARIOS
from repro.core.simulation import (EventQueue, SimConfig, SimLoadExecutor,
                                   Simulation)
from repro.core.variants import Application, WARMUP_S, synthetic_family

# ---------------------------------------------------------------------------
# golden fingerprint pinning
# ---------------------------------------------------------------------------

# sha256(repr(ScenarioResult.fingerprint())) of every named scenario at
# the pinned config below, captured BEFORE the model-state plane landed.
# The default (local-everything, uncontended) storage config must keep
# these bit-identical: any drift here means silent behavior change in
# the control or traffic plane — fail loudly, regenerate only on an
# INTENTIONAL behavior change (see docs/SCENARIOS.md).
GOLDEN_CFG = dict(n_sites=4, servers_per_site=5, headroom=0.2,
                  policy="faillite", seed=0)
GOLDEN_FINGERPRINTS = {
    "cascade":
        "9cbbd8f25e8487cda006fddc2dd06d5752dbf61bcfd6063c28ea6f15b3ccc505",
    "churn-under-failure":
        "d07affd60d507cfc16d31d4218b9ed43ee9c57241f7770dbf491da326341f0c3",
    "flaky-node":
        "a69ef08c40e96a2eb4478f85d55d903b4ccac64421237c3fafae137ad8ba5ffe",
    "rolling-with-rejoin":
        "3117397c14cd1e89badede74e4e48c3bb4cb1af8513d9d95019151ac762a79a8",
    "single-server":
        "a1511c4f54fbd40c0483787e76e18a95fad8e595b65344a952369006b6dc17ad",
    "site-outage":
        "c2a5d1ee7e2a8a8eb2652d1c15d208aef55010cc1ac5042b78f805093154bffc",
}


@pytest.mark.parametrize("name", sorted(GOLDEN_FINGERPRINTS))
def test_golden_scenario_fingerprints(name):
    sim = Simulation(SimConfig(**GOLDEN_CFG)).setup()
    res = sim.run_named_scenario(name)
    got = hashlib.sha256(repr(res.fingerprint()).encode()).hexdigest()
    assert got == GOLDEN_FINGERPRINTS[name], (
        f"{name}: scenario fingerprint drifted from the pinned golden "
        f"value — the default storage config must stay bit-identical")


def test_golden_covers_every_pre_modelstate_scenario():
    # every named scenario that predates the model-state plane is pinned
    # (cold-load-storm arrived with it, chaos with the soak harness, the
    # three resilience storms with the request-plane toolkit, and
    # tp-shard-storm with the shard plane)
    assert set(GOLDEN_FINGERPRINTS) == (
        set(SCENARIOS) - {"cold-load-storm", "chaos",
                          "retry-amplification", "thundering-herd-rejoin",
                          "metastable-overload", "tp-shard-storm"})


# ---------------------------------------------------------------------------
# storage config + registry
# ---------------------------------------------------------------------------

def _apps(n=3, mem=2e9):
    out = []
    for i in range(n):
        lad = synthetic_family(f"f{i}", mem, n_variants=4)
        out.append(Application(id=f"a{i}", family=f"f{i}", variants=lad,
                               request_rate=1.0, critical=(i == 0)))
    return out


def test_storage_presets_and_overrides():
    st = storage_preset("local")
    assert st.replicate_all and st.disk_bw == 8e9
    st = storage_preset("edge", cloud_bw=1e9, replication=3)
    assert not st.replicate_all
    assert st.cloud_bw == 1e9 and st.replication == 3
    with pytest.raises(KeyError):
        storage_preset("nope")


def test_registry_fetch_path_local_peer_cloud():
    cluster = make_cluster(2, 2, mem=16e9)
    reg = ModelRegistry(cluster, StorageConfig(
        nic_bw=1e9, cloud_bw=0.5e9, replicate_all=False, replication=2))
    v = synthetic_family("f", 1e9)[0]
    # nothing staged: cloud origin is the only copy
    plan = reg.fetch_plan(v.name, "s0-0")
    assert plan.source == CLOUD and plan.bw == 0.5e9
    # staged on a peer: same-site peer preferred over cloud
    reg.stage(v.name, "s0-1")
    plan = reg.fetch_plan(v.name, "s0-0")
    assert plan.source == PEER and plan.src_server == "s0-1"
    assert plan.bw == 1e9
    # staged locally: disk hit wins
    reg.stage(v.name, "s0-0")
    plan = reg.fetch_plan(v.name, "s0-0")
    assert plan.source == LOCAL
    # fetch_seconds orders local < peer < cloud
    t_local = reg.fetch_seconds(v, "s0-0")
    t_peer = reg.fetch_seconds(v, "s1-0")     # other site, peer copy
    reg2 = ModelRegistry(cluster, reg.storage)
    t_cloud = reg2.fetch_seconds(v, "s1-0")
    assert t_local < t_peer < t_cloud


def test_registry_seeding_spreads_across_sites():
    cluster = make_cluster(3, 2, mem=16e9)
    reg = ModelRegistry(cluster, StorageConfig(
        replicate_all=False, replication=2))
    app = _apps(1)[0]
    reg.ensure_app(app, "s0-0")
    res = reg.resident_servers(app.full.name)
    assert "s0-0" in res and len(res) == 2
    extra = next(iter(res - {"s0-0"}))
    # the extra replica lands OUTSIDE the primary's site
    assert cluster.servers[extra].site != "site0"


def test_load_cost_model_calibration():
    st = StorageConfig(disk_bw=8e9)
    m = LoadCostModel(st)
    v = synthetic_family("f", 8e9)[0]          # 8 GB full variant
    base = m.seconds(v, LOCAL, st.disk_bw)
    assert base == pytest.approx(v.mem_bytes / 8e9 + st.warmup_s)
    # observe a much slower real load -> effective bw drops -> estimate up
    m.observe(v, LOCAL, measured_s=4.0)
    assert m.seconds(v, LOCAL, st.disk_bw) > base
    assert LOCAL in m.to_dict() and m.n_obs == 1


# ---------------------------------------------------------------------------
# contention-aware load engine
# ---------------------------------------------------------------------------

def _engine(storage):
    clock = SimClock()
    events = EventQueue(clock)
    cluster = make_cluster(2, 2, mem=16e9)
    reg = ModelRegistry(cluster, storage)
    return events, SimLoadExecutor(events, registry=reg), reg


def test_default_storage_reduces_to_flat_load_cost():
    """Local-everything storage: load = bytes/bw + warmup, serialized
    per server — the exact pre-model-state cost model."""
    events, ex, _ = _engine(StorageConfig())
    v = synthetic_family("f", 4e9)[0]
    app = _apps(1)[0]
    done = []
    ex.load(app, v, "s0-0", done.append)
    ex.load(app, v, "s0-0", done.append)      # same disk: serializes
    ex.load(app, v, "s0-1", done.append)      # other disk: overlaps
    events.run_until(100.0)
    t1 = v.mem_bytes / 8e9 + WARMUP_S
    # completion order: the two parallel loads at t1, the queued one 2*t1
    assert sorted(done) == pytest.approx([t1, t1, 2 * t1])


def test_cloud_uplink_contention_serializes_concurrent_loads():
    st = StorageConfig(nic_bw=1e9, cloud_bw=0.5e9,
                       replicate_all=False, replication=1)
    events, ex, reg = _engine(st)
    v = synthetic_family("f", 1e9)[0]
    app = _apps(1)[0]
    done = []
    tickets = [ex.load(app, v, sid, done.append)
               for sid in ("s0-0", "s0-1", "s1-0")]
    events.run_until(100.0)
    # all three fetch from the cloud origin: the shared uplink drains
    # them back-to-back even though the target servers are distinct
    # (each slot is transfer + warmup, matching the per-server rule)
    slot = v.mem_bytes / 0.5e9 + st.warmup_s
    assert sorted(done) == pytest.approx([slot, 2 * slot, 3 * slot])
    assert [t.source for t in tickets] == [CLOUD, CLOUD, CLOUD]
    assert tickets[2].queue_s == pytest.approx(2 * slot)
    # the fetched bytes are now local: a reload is a disk hit
    assert reg.is_local(v.name, "s0-0")


def test_link_degrade_scales_bandwidth_for_window():
    st = StorageConfig(nic_bw=1e9, cloud_bw=1e9,
                       replicate_all=False, replication=1)
    events, ex, _ = _engine(st)
    v = synthetic_family("f", 1e9)[0]
    app = _apps(1)[0]
    ex.degrade_link("cloud", factor=0.5, duration=5.0)
    done = []
    ex.load(app, v, "s0-0", done.append)
    events.run_until(100.0)
    assert done[0] == pytest.approx(v.mem_bytes / 0.5e9 + st.warmup_s)
    # window expired: back to full bandwidth
    done2 = []
    ex.load(app, v, "s0-1", done2.append)
    events.run_until(200.0)
    assert done2[0] - 100.0 < v.mem_bytes / 0.9e9


# ---------------------------------------------------------------------------
# recovery scheduler
# ---------------------------------------------------------------------------

class _RecordingExecutor(LoadExecutor):
    """Manual-completion executor: records dispatch order."""

    def __init__(self):
        self.dispatched = []
        self._cbs = []

    def load(self, app, variant, server_id, on_ready):
        self.dispatched.append((app.id, variant.name, server_id))
        self._cbs.append(on_ready)
        return None

    def complete(self, i=0, t=1.0):
        self._cbs.pop(i)(t)


def _sched_apps():
    crit = _apps(1)[0]                      # a0: critical
    low = Application(id="low", family="f", request_rate=0.5,
                      variants=synthetic_family("g", 1e9))
    mid = Application(id="mid", family="f", request_rate=1.5,
                      variants=synthetic_family("h", 1e9))
    return crit, low, mid


def test_fifo_scheduler_dispatches_immediately_in_order():
    ex = _RecordingExecutor()
    sched = RecoveryScheduler(ex, mode="fifo")
    crit, low, mid = _sched_apps()
    for app in (low, mid, crit):
        sched.submit(app, app.full, "s0", lambda t: None)
    assert [d[0] for d in ex.dispatched] == ["low", "mid", "a0"]
    assert sched.idle()                     # fifo keeps no queue state


def test_criticality_scheduler_serializes_per_server_and_preempts():
    ex = _RecordingExecutor()
    sched = RecoveryScheduler(ex, mode="criticality")
    crit, low, mid = _sched_apps()
    # a non-critical load is mid-drain when higher-criticality work lands
    sched.submit(low, low.full, "s0", lambda t: None)
    assert len(ex.dispatched) == 1          # one in-flight per server
    sched.submit(mid, mid.full, "s0", lambda t: None)
    sched.submit(crit, crit.full, "s0", lambda t: None)
    assert len(ex.dispatched) == 1          # still queued behind low
    assert sched.n_pending == 3
    ex.complete()
    # preemption: the critical app (submitted LAST) jumps the queue
    assert ex.dispatched[1][0] == "a0"
    ex.complete()
    assert ex.dispatched[2][0] == "mid"
    ex.complete()
    assert sched.idle()


def test_criticality_scheduler_restores_before_upgrading():
    ex = _RecordingExecutor()
    sched = RecoveryScheduler(ex, mode="criticality")
    crit, low, _ = _sched_apps()
    # critical app's progressive pair: smallest restore + big upgrade
    sched.submit(crit, crit.smallest, "s0", lambda t: None)
    sched.submit(crit, crit.full, "s0", lambda t: None, stage=1)
    # a lower-criticality RESTORE arrives mid-drain
    sched.submit(low, low.smallest, "s0", lambda t: None)
    ex.complete()
    # low's restore beats crit's upgrade: availability before quality
    assert ex.dispatched[1][0] == "low"
    ex.complete()
    assert ex.dispatched[2] == ("a0", crit.full.name, "s0")
    ex.complete()


def test_criticality_scheduler_overlaps_across_servers():
    ex = _RecordingExecutor()
    sched = RecoveryScheduler(ex, mode="criticality")
    crit, low, _ = _sched_apps()
    sched.submit(low, low.full, "s0", lambda t: None)
    sched.submit(crit, crit.full, "s1", lambda t: None)
    assert len(ex.dispatched) == 2          # distinct servers overlap


def test_scheduler_drops_queue_of_dead_server():
    ex = _RecordingExecutor()
    alive = {"s0": True}
    sched = RecoveryScheduler(ex, mode="criticality",
                              alive_fn=lambda sid: alive[sid])
    crit, low, _ = _sched_apps()
    sched.submit(low, low.full, "s0", lambda t: None)
    sched.submit(crit, crit.full, "s0", lambda t: None)
    alive["s0"] = False
    sched.reset_server("s0")
    assert sched.idle()
    ex.complete()                           # stale completion: no crash
    assert len(ex.dispatched) == 1          # nothing new dispatched


# ---------------------------------------------------------------------------
# end-to-end: storm + locality + criticality
# ---------------------------------------------------------------------------

def _storm_cfg(**kw):
    base = dict(n_sites=4, servers_per_site=5, headroom=0.2, seed=0,
                storage="edge")
    base.update(kw)
    return SimConfig(**base)


def test_edge_storage_slows_cold_recovery():
    flat = Simulation(SimConfig(n_sites=4, servers_per_site=5,
                                headroom=0.2, seed=0)).setup()
    edge = Simulation(_storm_cfg()).setup()
    a = flat.run_named_scenario("cold-load-storm")
    b = edge.run_named_scenario("cold-load-storm")
    assert b.overall["mttr_avg"] > 2 * a.overall["mttr_avg"]
    # fetch sources show up in the records' phase breakdown
    srcs = {r.source for r in b.records if r.recovered and r.source}
    assert srcs & {PEER, CLOUD}


def test_criticality_and_locality_beat_fifo_greedy_on_storm():
    base = Simulation(_storm_cfg()).setup() \
        .run_named_scenario("cold-load-storm")
    tuned = Simulation(_storm_cfg(scheduler="criticality",
                                  planner="locality")).setup() \
        .run_named_scenario("cold-load-storm")
    assert tuned.overall["mttr_avg"] < base.overall["mttr_avg"]
    assert (tuned.traffic.client_mttr_avg
            < base.traffic.client_mttr_avg)


@pytest.mark.parametrize("scheduler", ["fifo", "criticality"])
def test_phase_breakdown_sums_to_mttr(scheduler):
    sim = Simulation(_storm_cfg(scheduler=scheduler)).setup()
    res = sim.run_named_scenario("cold-load-storm")
    cold = [r for r in res.records
            if r.recovered and r.mode.startswith("cold")]
    assert cold
    for r in cold:
        ph = r.phases
        assert set(ph) >= {"detect", "plan", "queue", "fetch", "warmup",
                           "route"}
        # sim time components reassemble the MTTR (plan runs in zero
        # sim time; its wall-clock cost is reported separately)
        total = (ph["detect"] + ph["queue"] + ph["fetch"] + ph["warmup"]
                 + ph["route"])
        assert total == pytest.approx(r.mttr, rel=1e-6)


def test_reprotect_rereplicates_underprotected_checkpoints():
    cfg = _storm_cfg(n_sites=3, servers_per_site=2, replication=2)
    sim = Simulation(cfg).setup()
    reg = sim.registry
    under = reg.under_replicated(sim.controller.apps.values())
    if not under:                            # seeding already satisfied
        # force under-replication by evicting one app's extra copy
        app = next(iter(sim.controller.apps.values()))
        v = app.smallest
        for sid in list(reg.resident_servers(v.name))[1:]:
            reg.evict(v.name, sid)
        under = reg.under_replicated([app])
    assert under
    for _ in range(40):                      # replication is rate-limited
        sim.controller.reprotect()
        sim.events.run_until(sim.clock.now() + 30.0)
        if not reg.under_replicated(sim.controller.apps.values()):
            break
    assert not reg.under_replicated(sim.controller.apps.values())


def test_locality_planner_prefers_resident_server():
    cluster = make_cluster(1, 4, mem=16e9)
    reg = ModelRegistry(cluster, StorageConfig(
        nic_bw=1e9, cloud_bw=0.5e9, replicate_all=False, replication=1))
    from repro.core.planner import PlanRequest, PlannerState, get_planner

    state = PlannerState(cluster)
    state.attach_registry(reg)
    app = _apps(1, mem=2e9)[0]
    for v in app.variants:
        reg.stage(v.name, "s0-2")            # only s0-2 holds the bytes
    req = PlanRequest(apps=[app], cluster=cluster, state=state,
                      primaries={})
    greedy = get_planner("greedy").plan(req).assignment[app.id]
    local = get_planner("locality").plan(req).assignment[app.id]
    assert greedy[1] == "s0-0"               # first-max tie-break
    assert local[1] == "s0-2"                # locality tie-break
    assert greedy[0].name == local[0].name   # same variant choice
    # the residency column view matches the registry
    mask = state.residency_mask(app.full.name)
    assert [state.server_ids[i] for i in mask.nonzero()[0]] == ["s0-2"]
